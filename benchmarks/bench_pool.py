"""Control-plane benchmark: every policy family over REAL jitted engines.

The apples-to-apples comparison the paper runs against Clipper/Nexus-style
baselines (§7): the same deterministic workload is served through the same
``EnginePool`` of slot-based engines by each policy family —

  temporal         pure time-sharing, full pod per run (Clipper/Nexus)
  fixed_batch_mps  uncontrolled spatial sharing (default MPS)
  maxmin / gslice  fair / static spatial partitioning
  dstack           the paper's dynamic fair spatio-temporal scheduler

Engines are compiled ONCE up front (one standby executable per candidate
allocation) and reused across all policies; the measured runs compile
nothing. Virtual time comes from the profile rooflines at each run's
granted allocation (deterministic, SLO-meaningful); every decode step is
still a real dispatch, and wall_s is the host time that took.

CLI: ``PYTHONPATH=src python benchmarks/bench_pool.py [--quick|--full]
[--faults]``; also wired into ``benchmarks/run.py`` as ``bench_pool``.
``--faults`` appends the chaos pass: a seeded ``FaultInjector`` (attached
AFTER warmup) drives transient dispatch faults, injected allocator
failures, and engine resets through a lazy pool serve, asserting the
ISSUE 6 acceptance bar end to end — the pool drains, pages conserve
(``check_page_invariants``), per-cause counters surface in the result,
and recovery compiles NOTHING.
"""
from __future__ import annotations

import time

try:                      # package context (benchmarks/run.py)
    from benchmarks import common as _common
except ImportError:       # script context (python benchmarks/bench_pool.py)
    import common as _common

MODELS_QUICK = ["qwen2-0.5b", "olmo-1b", "mamba2-1.3b"]
MODELS_FULL = MODELS_QUICK + ["whisper-small"]
POLICIES_QUICK = ["temporal", "fixed_batch_mps", "maxmin", "dstack"]
POLICIES_FULL = ["temporal", "fixed_batch_mps", "gslice", "triton",
                 "maxmin", "max_throughput", "dstack"]


def run(quick: bool = True):
    """``benchmarks/run.py`` entry point — CSV rows only."""
    rows, _ = run_with_results(quick)
    return rows


def run_faults(quick: bool = True):
    """The chaos pass (``--faults``): serve a lazy tight-page pool under
    a seeded fault schedule and assert the fault-tolerance acceptance
    invariants. Returns CSV rows like every other bench."""
    from repro.serving.controller import run_policy
    from repro.serving.faults import FaultInjector
    from repro.serving.pool import build_pool

    rate = 2000.0
    duration = 0.05 if quick else 0.25
    t0 = time.time()
    pool = build_pool(["olmo-1b"], request_rate=rate, base_slots=4,
                      cache_len=32, pages={"olmo-1b": 8}, lazy_kv=True)
    jit_before = pool.jit_cache_sizes()
    # attached AFTER warmup: the fault schedule must not depend on (or
    # perturb) compilation order, and recovery must reuse warm executables
    inj = FaultInjector(seed=17, dispatch_rate=0.05, alloc_rate=0.05,
                        max_faults=24)
    engines = [a.engine for h in pool.hosts.values()
               for a in h.allocations.values()]
    for eng in engines:
        eng.attach_faults(inj, max_retries=1)
    try:
        # drain mode: the acceptance bar is that a seeded chaos run
        # DRAINS — every request reaches a terminal state and every page
        # returns (a duration-cutoff run would leave legitimate
        # residents holding pages)
        res = run_policy(pool, "dstack", rate=rate, duration=duration,
                         gen_len=4, gen_tokens=(4, 20), drain=True)
    finally:
        for eng in engines:
            eng.attach_faults(None, max_retries=2)
    assert not res.truncated, "chaos run hit a controller backstop"
    m = res.per_model["olmo-1b"]
    rows = [("pool/faults/injected", (time.time() - t0) * 1e6,
             f"dispatch={inj.injected['dispatch']} "
             f"alloc={inj.injected['alloc']} "
             f"retries={m.engine_retries} resets={m.engine_resets}"),
            ("pool/faults/served", 0.0,
             f"served={m.completed} preempt={m.preemptions} "
             f"requeue={m.requeues} viol={m.violated}")]
    # the acceptance bar: chaos actually ran, the pool still served, no
    # page leaked, and recovery compiled nothing
    assert inj.total > 0, "fault schedule never fired"
    assert m.engine_retries > 0, "no transient fault was retried"
    assert m.completed > 0, "faulted pool served nothing"
    for eng in engines:
        assert eng.free_pages == eng.total_pages, "faulted pool leaked pages"
        eng.check_page_invariants()
    assert pool.jit_cache_sizes() == jit_before, "fault recovery recompiled"
    rows.append(("pool/faults/recompilations", 0.0, "0"))
    rows.append(("pool/faults/page_leaks", 0.0, "0"))
    return rows


def run_with_results(quick: bool = True):
    from repro.serving.controller import run_policy
    from repro.serving.pool import build_pool

    models = MODELS_QUICK if quick else MODELS_FULL
    policies = POLICIES_QUICK if quick else POLICIES_FULL
    rate = 2000.0
    duration = 0.05 if quick else 0.25
    gen_len = 4

    t0 = time.time()
    pool = build_pool(models, request_rate=rate, base_slots=4, cache_len=32)
    rows = [("pool/build_warm_s", (time.time() - t0) * 1e6,
             f"{len(models)} models, "
             f"{sum(len(h.allocations) for h in pool.hosts.values())} "
             f"standby engines")]
    jit_before = pool.jit_cache_sizes()

    results = []
    for pol in policies:
        res = run_policy(pool, pol, rate=rate, duration=duration,
                         gen_len=gen_len)
        assert not res.truncated, f"{pol} hit a controller backstop"
        results.append(res)
        rows.append((f"pool/{pol}/throughput", res.wall_s * 1e6,
                     f"{res.throughput():.1f} req/s virtual "
                     f"({res.total_completed} served)"))
        rows.append((f"pool/{pol}/violations", 0.0,
                     f"{res.total_violated}"))
        rows.append((f"pool/{pol}/jain_fairness", 0.0,
                     f"{res.fairness():.3f}"))
        rows.append((f"pool/{pol}/occupancy", 0.0, f"{res.occupancy:.3f}"))
        for n, m in sorted(res.per_model.items()):
            rows.append((f"pool/{pol}/{n.split('-')[0]}", 0.0,
                         f"served={m.completed} viol={m.violated} "
                         f"p50={m.p50 * 1e3:.2f}ms p99={m.p99 * 1e3:.2f}ms"
                         + (f" ttft_p50={m.ttft_p50 * 1e3:.2f}ms"
                            if m.ttfts else "")))

    # the acceptance invariant: standby executables were compiled up front;
    # serving every policy family recompiled NOTHING
    jit_after = pool.jit_cache_sizes()
    rows.append(("pool/recompilations", 0.0,
                 "0" if jit_after == jit_before else
                 f"CHANGED: {jit_before} -> {jit_after}"))
    assert jit_after == jit_before, "serving recompiled an executable"

    # lazy-KV pool: admission reserves prompt-only pages on a tight page
    # budget, decode grows page-by-page, and OutOfPages mid-run preempts
    # the newest resident and requeues its request — end to end through
    # the Controller, still with 0 recompiles (growth executables are
    # warmed up front like everything else)
    t0 = time.time()
    lazy = build_pool(["olmo-1b"], request_rate=rate, base_slots=4,
                      cache_len=32, pages={"olmo-1b": 8}, lazy_kv=True)
    jb = lazy.jit_cache_sizes()
    res = run_policy(lazy, "dstack", rate=rate, duration=duration,
                     gen_len=4, gen_tokens=(4, 20))
    m = res.per_model["olmo-1b"]
    rows.append(("pool/lazy_kv/preemptions", (time.time() - t0) * 1e6,
                 f"preempt={m.preemptions} requeue={m.requeues} "
                 f"served={m.completed} topups={m.topups} "
                 f"(8-page pool, ragged budgets 4..20)"))
    assert m.preemptions > 0 and m.requeues > 0, \
        "lazy pool never exercised preempt-and-requeue"
    assert lazy.jit_cache_sizes() == jb, "lazy serving recompiled"

    # radix prompt cache pool: pool prompts share their template by
    # construction, so every admission after the first can alias the
    # cached prefix pages. The gate here is the serving discipline —
    # hits, COW copies, and teacher-forced catch-up all ride executables
    # warmed up front (warm_prefix_ops), 0 recompiles; the token-savings
    # and bit-exactness bars live in bench_decode --shared-prefix
    t0 = time.time()
    pfx = build_pool(["olmo-1b"], request_rate=rate, base_slots=4,
                     cache_len=64, prompt_len=24, prefix_cache=True)
    jb = pfx.jit_cache_sizes()
    res = run_policy(pfx, "dstack", rate=rate, duration=duration,
                     gen_len=4)
    m = res.per_model["olmo-1b"]
    rows.append(("pool/prefix_cache/hits", (time.time() - t0) * 1e6,
                 f"hits={m.prefix_hits} aliased={m.prefix_hit_tokens}tok "
                 f"cow={m.cow_copies} served={m.completed}"))
    assert m.prefix_hits > 0, "prefix-cache pool never hit"
    assert pfx.jit_cache_sizes() == jb, "prefix-cache serving recompiled"
    return rows, results


def run_telemetry(quick: bool = True, trace_path=None):
    """The telemetry pass (always on under main()): serve the dstack
    policy with the ``Telemetry`` plane attached — wall-clock step timers
    behind block-until-ready on every dispatch — and join the measured
    per-(model, chips, kind, bucket) latencies against the
    ``core/latency_model`` rooflines (the ISSUE 7 roofline-validation
    report). With ``trace_path`` set a ``TraceRecorder`` also runs and
    the Perfetto-loadable Chrome trace is validated and written there.
    Attaching telemetry must neither recompile nor change behavior
    (asserted here via jit_cache_sizes; bit-identity is proved in
    tests/test_telemetry.py). Returns (rows, roofline rows, Prometheus
    text, PoolResult)."""
    from repro.serving.controller import run_policy
    from repro.serving.pool import build_pool
    from repro.serving.telemetry import (MetricsRegistry, Telemetry,
                                         TraceRecorder, export_pool_result,
                                         roofline_report,
                                         validate_chrome_trace)

    rate = 2000.0
    duration = 0.05 if quick else 0.25
    t0 = time.time()
    pool = build_pool(["qwen2-0.5b", "olmo-1b"], request_rate=rate,
                      base_slots=4, cache_len=32)
    jit_before = pool.jit_cache_sizes()
    # attached AFTER warmup (like faults): timing covers warm executables
    tel = Telemetry(trace=TraceRecorder() if trace_path else None)
    pool.attach_telemetry(tel)
    try:
        res = run_policy(pool, "dstack", rate=rate, duration=duration,
                         gen_len=4, gen_tokens=(4, 12))
    finally:
        pool.attach_telemetry(None)
    assert not res.truncated, "telemetry pass hit a controller backstop"
    assert pool.jit_cache_sizes() == jit_before, "telemetry recompiled"
    report = roofline_report(tel.timers, pool.profiles)
    assert report, "telemetry pass timed no dispatches"
    flagged = sum(1 for r in report if r.flagged)
    rows = [("pool/telemetry/dispatches_timed", (time.time() - t0) * 1e6,
             f"{tel.timers.total_samples} wall samples over "
             f"{len(tel.timers.samples)} (model,chips,kind,bucket) keys"),
            ("pool/telemetry/roofline_rows", 0.0,
             f"{len(report)} rows, {flagged} flagged at 4x tol "
             f"(CPU host vs TPU rooflines — deviations are the signal)")]
    # per-request streaming latency (TTFT/TBT), virtual time — the
    # figures end-to-end latency hides (satellite: RequestQueue TTFT)
    for n, m in sorted(res.per_model.items()):
        if m.ttfts:
            rows.append((f"pool/telemetry/{n.split('-')[0]}_ttft_p50",
                         m.ttft_p50 * 1e6,
                         f"p99={m.ttft_p99 * 1e6:.0f}us virtual "
                         f"(n={len(m.ttfts)}, "
                         f"tbt_p50={m.tbt_p50 * 1e6:.1f}us)"))
    reg = MetricsRegistry()
    export_pool_result(reg, res)
    prom = reg.render()
    if trace_path:
        obj = tel.trace.save(trace_path)
        n_spans = validate_chrome_trace(obj)
        rows.append(("pool/telemetry/trace", 0.0,
                     f"{len(obj['traceEvents'])} events ({n_spans} spans, "
                     f"{len(tel.trace.tracks())} tracks) -> {trace_path}"))
    return rows, report, prom, res


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized pass: 3 models, 4 policy families")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--faults", action="store_true",
                    help="append the seeded chaos pass (fault injection "
                         "through a lazy pool; asserts the ISSUE 6 "
                         "acceptance invariants)")
    ap.add_argument("--trace", nargs="?", const="trace_pool.json",
                    default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "telemetry pass to PATH (default "
                         "trace_pool.json)")
    ap.add_argument("--json", nargs="?", const="BENCH_pool.json",
                    default=None, metavar="PATH", dest="json_out",
                    help="write rows + roofline report + Prometheus "
                         "snapshot as dstack-bench-v1 JSON (default "
                         "BENCH_pool.json)")
    args = ap.parse_args()
    quick = not args.full
    rows, results = run_with_results(quick)
    if args.faults:
        rows += run_faults(quick)
    trows, report, prom, _ = run_telemetry(quick, trace_path=args.trace)
    rows += trows
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    print()
    from repro.serving.telemetry import format_roofline
    print("roofline validation (measured wall-clock vs latency_model)")
    for line in format_roofline(report):
        print(line)
    print()
    print("policy           summary (virtual time; real jitted engines)")
    for res in results:
        for line in res.table_rows():
            print(line)
    if args.json_out:
        payload = _common.bench_payload(
            "bench_pool", rows,
            args={"quick": quick, "faults": bool(args.faults),
                  "trace": bool(args.trace)},
            extra={"roofline": [r.as_dict() for r in report],
                   "prometheus": prom})
        _common.write_json(args.json_out, payload)
        print(f"wrote {args.json_out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
