"""Paper Table 1: task-completion time, 4 models x N requests each,
Triton-style dynamic batching vs D-STACK."""
from __future__ import annotations

from benchmarks.common import C4, Burst, profiles_for, timed
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimConfig, Simulator


def run(quick: bool = True):
    n_req = 2_000 if quick else 10_000
    rows = []
    makespans = {}
    for pol in ("triton", "dstack"):
        profiles = profiles_for(C4)
        gens = [Burst(n, n_req, profiles[n].slo) for n in profiles]
        sim = Simulator(profiles, POLICIES[pol](profiles), gens,
                        SimConfig(drain=True, drop_expired=False, duration=0))
        res, us = timed(sim.run)
        assert res.total_completed == n_req * len(C4)
        makespans[pol] = res.makespan
        rows.append((f"table1/{pol}_completion_s", us,
                     f"{res.makespan:.3f}"))
    reduction = 100 * (1 - makespans["dstack"] / makespans["triton"])
    rows.append(("table1/latency_reduction_pct", 0.0, f"{reduction:.1f}"))
    return rows
