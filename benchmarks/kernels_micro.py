"""Kernel microbenchmarks (CPU wall time of the jnp production paths +
parity stats vs the oracles). On TPU these would time the Pallas kernels."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _bench(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    b, s, h, kv, d = 1, 1024, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, backend="jnp"))
    us = _bench(fa, q, k, v)
    err = float(jnp.abs(fa(q, k, v)
                        - ref.attention_ref(q, k, v, causal=True)).max())
    rows.append((f"kernels/flash_jnp_b{b}s{s}", us, f"maxerr={err:.2e}"))

    bs, l, hh, p, n = 1, 512, 4, 64, 64
    x = jax.random.normal(ks[3], (bs, l, hh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (bs, l, hh)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[5], (hh,)))
    bb = jax.random.normal(ks[6], (bs, l, n))
    cc = jax.random.normal(ks[7], (bs, l, n))
    ssd = jax.jit(lambda *t: ops.ssd(*t, chunk=128, backend="jnp")[0])
    us = _bench(ssd, x, dt, a, bb, cc)
    y_ref, _ = ref.ssd_ref(x, dt, a, bb, cc)
    err = float(jnp.abs(ssd(x, dt, a, bb, cc) - y_ref).max())
    rows.append((f"kernels/ssd_chunked_b{bs}l{l}", us, f"maxerr={err:.2e}"))

    # sequential-oracle speedup (the SSD state-space-duality win)
    seq = jax.jit(lambda *t: ref.ssd_ref(*t)[0])
    us_seq = _bench(seq, x, dt, a, bb, cc)
    rows.append(("kernels/ssd_chunked_speedup_vs_sequential", 0.0,
                 f"{us_seq/us:.1f}x"))
    return rows
