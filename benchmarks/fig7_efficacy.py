"""Paper Fig. 7/8: efficacy surface over (batch, allocation) and the
SLO-feasible optimal operating point per architecture."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.configs import ARCHS
from repro.core.efficacy import BATCH_LEVELS, efficacy_surface, optimize
from repro.core.latency_model import CHIP_LEVELS, LatencyModel
from repro.core.profiles import DEFAULT_SLOS


def run(quick: bool = True):
    rows = []
    for arch in ("mamba2-1.3b", "deepseek-7b") if quick else list(ARCHS):
        cfg = ARCHS[arch]
        lm = LatencyModel(cfg, mode="prefill", seq=128)
        (grid, us) = timed(efficacy_surface, lm)
        bi, ci = np.unravel_index(np.argmax(grid), grid.shape)
        rows.append((f"fig7/{arch}/unconstrained_peak", us,
                     f"b={BATCH_LEVELS[bi]},c={CHIP_LEVELS[ci]}"))
        slo = DEFAULT_SLOS[cfg.name]
        pt = optimize(lm, slo=slo, request_rate=2000)
        rows.append((f"fig8/{arch}/slo_optimal", 0.0,
                     f"b={pt.batch},c={pt.chips},lat={pt.latency*1e3:.2f}ms,"
                     f"feasible={pt.feasible}"))
        # interior-batch property: batch-1 efficacy below peak at fixed chips
        j = CHIP_LEVELS.index(max(pt.chips, 8))
        col = grid[:, j]
        rows.append((f"fig7/{arch}/batch1_vs_peak", 0.0,
                     f"{col[0]/max(col.max(), 1e-9):.3f}"))
    return rows
