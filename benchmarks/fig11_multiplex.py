"""Paper Fig. 11a: C-2/C-3/C-4/C-7 multiplexing — throughput + SLO
violations across FB-MPS / temporal / Triton / GSLICE / D-STACK; and
Fig. 11b: dynamic request-rate adaptation under D-STACK."""
from __future__ import annotations

from benchmarks.common import generators_for, profiles_for, timed
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimConfig, Simulator
from repro.serving.request import RequestGenerator

CASES = {
    "C-2": ["deepseek-7b", "yi-9b"],
    "C-3": ["deepseek-7b", "yi-9b", "qwen2-0.5b"],
    "C-4": ["deepseek-7b", "yi-9b", "qwen2-0.5b", "mamba2-1.3b"],
    "C-7": ["deepseek-7b", "yi-9b", "qwen2-0.5b", "mamba2-1.3b",
            "olmo-1b", "granite-moe-3b-a800m", "whisper-small"],
}
POLS = ("fixed_batch_mps", "temporal", "triton", "gslice", "dstack")
RATE = 3000


def run(quick: bool = True):
    dur = 1.0 if quick else 10.0
    rows = []
    for case, names in CASES.items():
        if quick and case in ("C-2", "C-3"):
            continue
        for pol in POLS:
            profiles = profiles_for(names, rate=RATE)
            sim = Simulator(profiles, POLICIES[pol](profiles),
                            generators_for(profiles, RATE),
                            SimConfig(duration=dur))
            res, us = timed(sim.run)
            offered = res.total_completed + res.total_violated
            rows.append((f"fig11a/{case}/{pol}", us,
                         f"thr={res.throughput():.0f};"
                         f"violpct={100*res.total_violated/max(offered,1):.1f};"
                         f"util={res.utilization:.2f}"))
    # Fig. 11b: one model's rate drops mid-run; others absorb the slack
    profiles = profiles_for(CASES["C-4"], rate=RATE)
    gens = generators_for(profiles, RATE)

    class VaryRate:
        def __init__(self, inner: RequestGenerator, t_drop: float):
            self.inner, self.t_drop, self._dropped = inner, t_drop, False

        def until(self, t_end):
            if not self._dropped and t_end >= self.t_drop:
                self.inner.set_rate(self.inner.rate * 0.2)
                self._dropped = True
            return self.inner.until(t_end)

    gens[0] = VaryRate(gens[0], dur / 2)
    sim = Simulator(profiles, POLICIES["dstack"](profiles), gens,
                    SimConfig(duration=dur))
    res, us = timed(sim.run)
    rows.append(("fig11b/dynamic_rate/utilization", us,
                 f"{res.utilization:.3f}"))
    rows.append(("fig11b/dynamic_rate/throughput", 0.0,
                 f"{res.throughput():.0f}"))
    return rows
