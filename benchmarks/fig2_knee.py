"""Paper Fig. 2/3: latency vs allocation-fraction curves and the knee per
architecture (batch = 16, prefill-128 serving unit)."""
from __future__ import annotations

from benchmarks.common import timed
from repro.configs import ARCHS
from repro.core.latency_model import CHIP_LEVELS, LatencyModel


def run(quick: bool = True):
    rows = []
    for arch, cfg in ARCHS.items():
        lm = LatencyModel(cfg, mode="prefill", seq=128)
        (knee, us) = timed(lm.knee_chips, 16)
        lat_knee = lm.latency(knee, 16)
        lat_full = lm.latency(256, 16)
        curve = ";".join(
            f"{c}:{lm.latency(c, 16)*1e3:.2f}" for c in CHIP_LEVELS
            if lm.latency(c, 16) != float("inf"))
        rows.append((f"fig2/{arch}/knee_frac", us, f"{knee/256:.3f}"))
        rows.append((f"fig2/{arch}/lat_knee_over_full", 0.0,
                     f"{lat_knee/lat_full:.3f}"))
        rows.append((f"fig2/{arch}/curve_ms", 0.0, curve))
    return rows
