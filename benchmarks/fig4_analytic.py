"""Paper Fig. 4a/4b: the analytical DNN model — E_t(S) curves for varying
inherent parallelism and the derivative maxima locating the knee."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.knee import AnalyticalDNN


def run(quick: bool = True):
    rows = []
    s = np.arange(1, 81)
    for n1 in (20, 40, 60):
        m = AnalyticalDNN(p=n1, mem_bw_per_unit=50.0, data_per_kernel=100.0)
        (et, us) = timed(m.execution_time, s)
        d = m.derivative_curve(s)
        k = int(s[np.argmax(d)])
        rows.append((f"fig4/N1={n1}/knee_units", us, str(k)))
        rows.append((f"fig4/N1={n1}/Et_1_vs_knee", 0.0,
                     f"{float(et[0]/et[k-1]):.2f}"))
    # Fig. 4c/4d: batch dependence
    for b in (1, 2, 4, 8):
        m = AnalyticalDNN(p=10, b=b)
        rows.append((f"fig4/batch={b}/knee_units", 0.0, str(m.knee(128))))
    return rows
