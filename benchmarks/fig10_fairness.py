"""Paper Fig. 10: throughput + per-model GPU runtime under temporal /
max-throughput / max-min / D-STACK — the fairness comparison."""
from __future__ import annotations

from benchmarks.common import C4, generators_for, profiles_for, timed
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimConfig, Simulator

RATE = 4000


def run(quick: bool = True):
    dur = 1.5 if quick else 10.0
    rows = []
    runtimes = {}
    for pol in ("temporal", "max_throughput", "maxmin", "dstack"):
        profiles = profiles_for(C4, rate=RATE)
        sim = Simulator(profiles, POLICIES[pol](profiles),
                        generators_for(profiles, RATE),
                        SimConfig(duration=dur))
        res, us = timed(sim.run)
        rows.append((f"fig10/{pol}/throughput", us, f"{res.throughput():.1f}"))
        per = {n: m.runtime for n, m in res.per_model.items()}
        runtimes[pol] = per
        rows.append((f"fig10/{pol}/runtime_s", 0.0,
                     ";".join(f"{n.split('-')[0]}:{v:.2f}"
                              for n, v in per.items())))
    # fairness index (Jain) over per-model runtimes
    for pol, per in runtimes.items():
        vals = list(per.values())
        jain = (sum(vals) ** 2) / (len(vals) * sum(v * v for v in vals) + 1e-12)
        rows.append((f"fig10/{pol}/jain_fairness", 0.0, f"{jain:.3f}"))
    return rows
