"""Paper Fig. 9a-d: schedule comparison at the shared knee/batch operating
point — temporal vs GSLICE vs D-STACK vs the preemptive ideal bound."""
from __future__ import annotations

import dataclasses

from benchmarks.common import C4, generators_for, profiles_for, timed
from repro.core.scheduler import POLICIES, IdealSimulator
from repro.core.simulator import SimConfig, Simulator

RATE = 1000


def _pinned_profiles():
    out = {}
    for n, p in profiles_for(C4, rate=RATE).items():
        out[n] = dataclasses.replace(p, opt_chips=p.knee_chips, opt_batch=16)
    return out


def run(quick: bool = True):
    dur = 1.5 if quick else 10.0
    rows = []
    results = {}
    for pol in ("temporal", "gslice", "dstack"):
        profiles = _pinned_profiles()
        sim = Simulator(profiles, POLICIES[pol](profiles),
                        generators_for(profiles, RATE),
                        SimConfig(duration=dur))
        res, us = timed(sim.run)
        results[pol] = res
        rows.append((f"fig9/{pol}/utilization", us, f"{res.utilization:.3f}"))
        rows.append((f"fig9/{pol}/throughput", 0.0,
                     f"{res.throughput():.1f}"))
    profiles = _pinned_profiles()
    ideal, us = timed(
        IdealSimulator(profiles, generators_for(profiles, RATE),
                       duration=dur).run)
    rows.append(("fig9/ideal/utilization", us, f"{ideal.utilization:.3f}"))
    rows.append(("fig9/ideal/throughput", 0.0, f"{ideal.throughput():.1f}"))
    rows.append(("fig9/dstack_over_ideal_throughput", 0.0,
                 f"{results['dstack'].throughput()/ideal.throughput():.3f}"))
    rows.append(("fig9/dstack_over_ideal_utilization", 0.0,
                 f"{results['dstack'].utilization/ideal.utilization:.3f}"))
    return rows
