"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.profiles import ModelProfile, build_profile
from repro.serving.request import Request, RequestGenerator

# the paper's 4-model mix (Alexnet/Mobilenet/ResNet-50/VGG-19 analog:
# two lightweight, one mid, one heavy)
C4 = ["qwen2-0.5b", "mamba2-1.3b", "deepseek-7b", "yi-9b"]
C7 = C4 + ["olmo-1b", "granite-moe-3b-a800m", "whisper-small"]


def profiles_for(names, rate=2000) -> Dict[str, ModelProfile]:
    return {n: build_profile(n, request_rate=rate) for n in names}


def generators_for(profiles, rate=2000, seed0=0):
    return [RequestGenerator(n, rate, profiles[n].slo, seed=seed0 + i)
            for i, n in enumerate(profiles)]


class Burst:
    """All requests at t=0 — fixed-work (Table 1) workloads."""

    def __init__(self, model: str, n: int, slo: float):
        self.reqs = [Request(0.0, i, model, slo) for i in range(n)]

    def until(self, t_end: float) -> List[Request]:
        out, self.reqs = self.reqs, []
        return out


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


# --------------------------------------------------------------------------
# shared machine-readable bench output (ISSUE 7): one schema for every
# benchmark's --json flag, so BENCH_*.json files form a comparable
# trajectory across PRs. CI validates each emitted file round-trips
# through validate_bench_json.
# --------------------------------------------------------------------------
BENCH_SCHEMA = "dstack-bench-v1"


def bench_payload(bench: str, rows, args=None, extra=None) -> dict:
    """Wrap a benchmark's ``(name, us_per_call, derived)`` rows in the
    shared schema. ``args`` records the CLI shape that produced the
    numbers (quick vs full runs are not comparable); ``extra`` carries
    bench-specific structured sections (roofline report, Prometheus
    snapshot, ...)."""
    return {
        "schema": BENCH_SCHEMA,
        "bench": str(bench),
        "args": dict(args or {}),
        "rows": [{"name": str(n), "us_per_call": float(us),
                  "derived": str(d)} for n, us, d in rows],
        "extra": dict(extra or {}),
    }


def write_json(path: str, payload: dict) -> dict:
    import json
    validate_bench_json(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def validate_bench_json(payload) -> int:
    """Schema gate for the perf trajectory; returns the row count.
    Raises ``ValueError`` on the first violation."""
    if not isinstance(payload, dict):
        raise ValueError("bench json: not an object")
    if payload.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bench json: schema {payload.get('schema')!r} "
                         f"!= {BENCH_SCHEMA!r}")
    if not payload.get("bench"):
        raise ValueError("bench json: missing bench name")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("bench json: rows missing or empty")
    for i, r in enumerate(rows):
        if not isinstance(r, dict) or not r.get("name"):
            raise ValueError(f"bench json: rows[{i}] malformed")
        us = r.get("us_per_call")
        if not isinstance(us, (int, float)) or us < 0:
            raise ValueError(f"bench json: rows[{i}].us_per_call {us!r}")
        if "derived" not in r:
            raise ValueError(f"bench json: rows[{i}] missing derived")
    for k in ("args", "extra"):
        if not isinstance(payload.get(k, {}), dict):
            raise ValueError(f"bench json: {k} is not an object")
    return len(rows)
