"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.profiles import ModelProfile, build_profile
from repro.serving.request import Request, RequestGenerator

# the paper's 4-model mix (Alexnet/Mobilenet/ResNet-50/VGG-19 analog:
# two lightweight, one mid, one heavy)
C4 = ["qwen2-0.5b", "mamba2-1.3b", "deepseek-7b", "yi-9b"]
C7 = C4 + ["olmo-1b", "granite-moe-3b-a800m", "whisper-small"]


def profiles_for(names, rate=2000) -> Dict[str, ModelProfile]:
    return {n: build_profile(n, request_rate=rate) for n in names}


def generators_for(profiles, rate=2000, seed0=0):
    return [RequestGenerator(n, rate, profiles[n].slo, seed=seed0 + i)
            for i, n in enumerate(profiles)]


class Burst:
    """All requests at t=0 — fixed-work (Table 1) workloads."""

    def __init__(self, model: str, n: int, slo: float):
        self.reqs = [Request(0.0, i, model, slo) for i in range(n)]

    def until(self, t_end: float) -> List[Request]:
        out, self.reqs = self.reqs, []
        return out


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
