"""§Roofline: three-term roofline per (arch × shape) from the dry-run's
compiled artifacts (results/dryrun/*.json), single-pod mesh.

  compute    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = collective_bytes(per-device) / link_bw (2 usable directions)

plus MODEL_FLOPS = 6·N·D (6·N_active·D for MoE; 2·N·D for inference) and
the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × devices).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.core.hardware import V5E

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token


def load_records(mesh: str = "16x16") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_terms(rec: dict) -> Optional[dict]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    hw = V5E
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    n_dev = rec.get("n_devices", 256)
    # prefer trip-count-weighted costs (XLA cost_analysis counts while
    # bodies once — a ~num_layers under-report for scanned models)
    flops = rec.get("weighted_flops_per_device", rec["flops_per_device"])
    byts = rec.get("weighted_bytes_per_device", rec["bytes_per_device"])
    coll = rec.get("weighted_collective_bytes", rec["collective_bytes"])
    t_comp = flops / hw.peak_flops
    t_mem = byts / hw.hbm_bw
    # shapes in the partitioned module are per-device shards: a ring
    # all-reduce moves ~2x the shard per chip; all-to-all moves ~1x
    ar = sum(v for k, v in coll.items() if k != "all-to-all")
    a2a = coll.get("all-to-all", 0.0)
    t_coll = (2.0 * ar + a2a) / (hw.ici_bw * 2)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops * n_dev
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_total, 1.0),
        "mem_gb_per_device": rec["memory"]["total_per_device"] / 1e9,
        "fits_hbm": rec["memory"]["total_per_device"] <= hw.hbm_bytes,
    }


def run(quick: bool = True):
    rows = []
    n_fit = n_all = 0
    for rec in load_records("16x16"):
        rt = roofline_terms(rec)
        tag = f"roofline/{rec['arch']}/{rec['shape']}"
        if rt is None:
            rows.append((tag, 0.0, "skipped"))
            continue
        n_all += 1
        n_fit += int(rt["fits_hbm"])
        rows.append((
            tag, 0.0,
            f"comp={rt['compute_s']*1e3:.2f}ms;mem={rt['memory_s']*1e3:.2f}ms;"
            f"coll={rt['collective_s']*1e3:.2f}ms;dom={rt['dominant']};"
            f"useful={rt['useful_ratio']:.2f};"
            f"hbm={rt['mem_gb_per_device']:.1f}GB;fits={rt['fits_hbm']}"))
    rows.append(("roofline/fits_hbm_count", 0.0, f"{n_fit}/{n_all}"))
    return rows
