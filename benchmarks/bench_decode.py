"""Decode hot-path microbenchmarks: the wins this repo's serving stack
actually banks on.

1. **scan vs eager generation** — tokens/s of the fused
   ``jax.lax.scan`` token loop (ONE dispatch per generate call, donated
   cache) against the per-token Python loop (one dispatch per token).
   The paper's multiplexing math assumes the data plane is
   dispatch-bound on the device, not the host; this row verifies it.

2. **ragged vs pad-to-max decode attention** — with per-sequence
   lengths, attention work scales with each row's ACTUAL length instead
   of every row paying for the longest. On CPU the win is realized by
   host-side length-bucketing over the jnp path (lengths are known on
   the host in the serving engine); on TPU the same ``(B,)`` vector
   drives the Pallas kernel's per-row cache-block skip + DMA clamp, so
   the saving is intrinsic to one launch (the kernel's block arithmetic
   is reported in the derived column; interpret-mode per-block overheads
   make direct kernel timing on CPU meaningless).

3. **ring vs paged KV slots** (``--paged``) — the same mixed-length
   request stream served by the ring-slot engine and by the paged engine
   at an EQUAL KV byte budget. Rings pin ``cache_len`` per admitted
   request no matter how little it generates; pages pin only the
   request's prompt + token budget, so more sequences are resident at
   once (deeper continuous batch → fewer dispatches per served token)
   and KV bytes per resident request drop. Reported: tokens/s, peak
   resident sequences, and KV bytes per resident request for both.

4. **radix prompt cache** (``--shared-prefix``) — a heavy-tailed
   stream of prompts sharing a few popular templates, served with the
   prefix cache off and on. Hits alias cached KV pages (refcounted,
   copy-on-write on sub-page divergence) instead of re-prefilling, so
   admission prefill tokens drop and — at a tight page budget — more
   sequences are resident at once, while the greedy streams stay
   bit-identical and zero recompiles occur.

5. **speculative decoding** (``--speculative``) — an identical-weights
   draft twin drafts ``spec_k`` tokens per decoding slot in one fused
   scan dispatch and the target verifies all of them in one packed
   incremental chunk-attention dispatch: up to ``spec_k + 1`` tokens
   per slot for 2 dispatches where plain greedy pays one dispatch per
   token. Streams stay bit-exact, acceptance is 1.0 by construction,
   and the >1.5x decode-tokens/s gate is asserted.

CLI: ``python benchmarks/bench_decode.py [--smoke|--full|--paged]``
(``--paged`` runs section 3 alone; the default modes include it); also
wired into ``benchmarks/run.py`` and the CI smoke.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_generate(rows, *, batch_size: int, gen_tokens: int, iters: int,
                   prompt_lens=(24, 40, 56, 72), base_cache: int = 32):
    """Serve a stream of varying-prompt-length generate calls.

    The eager baseline reproduces the seed engine end to end: one jitted
    dispatch per token AND a fresh exact-length prefill jit whenever
    ``prompt + gen`` exceeds the base cache (i.e. per request). The scan
    path pays one dispatch per call against pow2-bucketed executables that
    the warmup has already compiled — which is exactly the steady state a
    serving engine lives in."""
    from repro.configs import get_config
    from repro.serving.engine import make_engine

    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=base_cache)
    batches = [{"tokens": jnp.ones((batch_size, s), jnp.int32)}
               for s in prompt_lens]

    for b in batches:                              # warm every scan bucket
        eng.generate(b, gen_tokens)

    def stream(fn):
        out = None
        for b in batches:
            out = fn(b, gen_tokens)
        return out

    t_eager = _time(lambda: stream(eng.generate_eager), iters=iters)
    t_scan = _time(lambda: stream(eng.generate), iters=iters)
    toks = batch_size * gen_tokens * len(batches)
    rows.append((f"decode/generate_eager_b{batch_size}t{gen_tokens}",
                 t_eager * 1e6, f"{toks / t_eager:.0f} tok/s"))
    rows.append((f"decode/generate_scan_b{batch_size}t{gen_tokens}",
                 t_scan * 1e6, f"{toks / t_scan:.0f} tok/s"))
    rows.append(("decode/scan_speedup_vs_eager", 0.0,
                 f"{t_eager / t_scan:.1f}x"))

    # fixed-shape slice: dispatch-per-token elimination alone (no re-jit
    # in either path — prompt + gen exactly fits the base cache). On tiny
    # smoke shapes the wall ratio is host-noise (~1.0x), so the derived
    # column leads with the deterministic quantity — the dispatch counts
    # the scan loop collapses — and carries the wall ratio alongside.
    p = max(1, base_cache // 4)
    n_gen = base_cache - p
    small = {"tokens": jnp.ones((batch_size, p), jnp.int32)}
    t_e1 = _time(lambda: eng.generate_eager(small, n_gen), iters=iters)
    t_s1 = _time(lambda: eng.generate(small, n_gen), iters=iters)
    rows.append(("decode/scan_speedup_fixed_shape", 0.0,
                 f"{n_gen} decode dispatches vs 1 ({n_gen}x fewer; "
                 f"{t_e1 / t_s1:.1f}x wall)"))
    return t_eager / t_scan


def bench_ragged(rows, *, cache_len: int, block_k: int, iters: int):
    import numpy as np
    from repro.models.layers import decode_attention as jnp_decode

    b, h, kv, d = 8, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, cache_len, kv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, cache_len, kv, d), jnp.float32)
    # mixed-length batch: a few short rows, a couple of long ones
    lengths = np.array([cache_len // 16, cache_len // 16, cache_len // 8,
                        cache_len // 8, cache_len // 4, cache_len // 4,
                        cache_len // 2, cache_len])

    # pad-to-max: one launch, every row attends over the full cache
    padded = jax.jit(lambda q, k, v: jnp_decode(q, k, v, cache_len))

    # ragged: bucketed cache layout — rows grouped by pow2 length bucket
    # (a slot engine keeps slots bucket-contiguous, so the grouping exists
    # a priori); each group attends only over its bucket's cache prefix
    groups = []
    fn = jax.jit(lambda q, k, v, l: jnp_decode(q, k, v, l))
    for bkt in sorted({1 << (int(ln) - 1).bit_length() for ln in lengths}):
        ia = np.array([i for i, ln in enumerate(lengths)
                       if bkt // 2 < ln <= bkt])
        if ia.size:
            groups.append((q[ia], kc[ia, :bkt], vc[ia, :bkt],
                           jnp.asarray(lengths[ia], jnp.int32)))

    def ragged():
        return [fn(*g) for g in groups]

    jax.block_until_ready(ragged())               # warm every bucket shape
    t_pad = _time(padded, q, kc, vc, iters=iters)
    t_rag = _time(lambda: jax.block_until_ready(ragged()), iters=iters)

    # what the Pallas kernel's per-row block skip saves in one launch
    blocks_pad = b * (cache_len // block_k)
    blocks_rag = int(sum(-(-int(ln) // block_k) for ln in lengths))
    rows.append((f"decode/attn_pad_to_max_c{cache_len}", t_pad * 1e6,
                 f"valid={cache_len} all rows"))
    rows.append((f"decode/attn_ragged_c{cache_len}", t_rag * 1e6,
                 f"lengths {int(lengths.min())}..{int(lengths.max())}"))
    rows.append(("decode/ragged_speedup_vs_padded", 0.0,
                 f"{t_pad / t_rag:.1f}x"))
    rows.append(("decode/ragged_kernel_blocks", 0.0,
                 f"{blocks_rag}/{blocks_pad} cache blocks "
                 f"({blocks_pad / blocks_rag:.1f}x fewer)"))
    return t_pad / t_rag


def bench_paged(rows, *, n_slots: int, cache_len: int, page_size: int,
                n_requests: int, gen_range, iters: int = 1):
    """Serve one mixed-length stream through ring slots and through paged
    slots holding the SAME KV page budget (ring bytes == paged pool
    bytes); the paged engine gets surplus slot rows (cheap: a slot row is
    bookkeeping + lane, pages are the memory) and lets admission be gated
    by pages instead.
    """
    import numpy as np
    from repro.configs import get_config
    from repro.serving.engine import make_engine

    cfg = get_config("olmo-1b").reduced()
    prompt_len = 8
    total_pages = n_slots * (cache_len // page_size)
    rng = np.random.default_rng(0)
    budgets = rng.integers(gen_range[0], gen_range[1] + 1,
                           size=n_requests).tolist()
    prompt = {"tokens": jnp.ones((1, prompt_len), jnp.int32)}

    def serve(eng):
        """Continuous-batching loop: admit whatever fits, step, free done
        slots as their ragged budgets exhaust."""
        nxt = 0
        served = steps = 0
        peak = 0
        while served < n_requests:
            while nxt < n_requests and eng.can_admit(prompt_len,
                                                     budgets[nxt]):
                eng.insert(prompt, n_tokens=budgets[nxt])
                nxt += 1
            peak = max(peak, eng.n_slots - eng.free_slots)
            _, done = eng.step()
            steps += 1
            for slot in done:
                eng.free(slot)
                served += 1
        return steps, peak

    results = {}
    for mode in ("ring", "paged"):
        if mode == "ring":
            eng = make_engine(cfg, cache_len=cache_len).init_slots(
                n_slots, paged=False)
        else:
            eng = make_engine(cfg, cache_len=cache_len).init_slots(
                4 * n_slots, paged=True, page_size=page_size,
                total_pages=total_pages)
        steps, peak = serve(eng)    # warm + stats (serve is deterministic)
        t = _time(lambda e=eng: serve(e), iters=iters)
        toks = sum(budgets)
        kv_bytes = eng.kv_cache_bytes()
        results[mode] = (t, steps, peak, kv_bytes)
        rows.append((f"decode/{mode}_slots_tok_s", t * 1e6,
                     f"{toks / t:.0f} tok/s ({steps} dispatches)"))
        rows.append((f"decode/{mode}_peak_resident", 0.0,
                     f"{peak} seqs in {kv_bytes / 1e6:.2f} MB KV "
                     f"({kv_bytes / max(1, peak) / 1e3:.0f} KB/seq)"))
    (t_r, st_r, pk_r, by_r), (t_p, st_p, pk_p, by_p) = (results["ring"],
                                                        results["paged"])
    rows.append(("decode/paged_resident_ratio", 0.0,
                 f"{pk_p / max(1, pk_r):.2f}x more resident seqs at "
                 f"equal page budget"))
    rows.append(("decode/paged_kv_bytes_per_seq_ratio", 0.0,
                 f"{(by_r / max(1, pk_r)) / (by_p / max(1, pk_p)):.2f}x "
                 f"fewer KV bytes per resident seq"))
    rows.append(("decode/paged_speedup_vs_ring", 0.0,
                 f"{t_r / t_p:.2f}x tokens/s"))
    return pk_p / max(1, pk_r)


def bench_packed_prefill(rows, *, batch_size: int, cache_len: int,
                         len_range, n_batches: int, iters: int):
    """Packed ragged prefill vs pad-to-max on a mixed-length prompt stream.

    The padded baseline is what the engine did before: every prompt in an
    admission batch padded to the batch's pow2 length bucket, one (B, max)
    prefill dispatch. The packed path concatenates the same prompts into
    one (1, sum-of-lens bucketed) row with segment ids. Tokens/s is
    counted over REAL prompt tokens for both, so padding waste shows up as
    lost throughput, exactly as it does on the accelerator.

    The stream is heavy-tailed (70% of prompts from the low third of
    ``len_range``, 30% from the high end) — the shape real prompt-length
    traces have, and the regime the padded path handles worst: one long
    prompt drags every short prompt in its batch up to the long bucket,
    while the packed row grows only by the actual tokens. Also reports
    the admission-side dispatch counts: ``insert_many`` must prefill each
    admission batch in ONE dispatch (asserted via engine stats) where
    sequential ``insert`` pays one per request."""
    import numpy as np
    from repro.configs import get_config
    from repro.serving.engine import _pow2_at_least, make_engine

    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=cache_len).init_slots(
        batch_size, paged=True, page_size=8)
    rng = np.random.default_rng(0)
    lo, hi = len_range
    cut = lo + (hi - lo) // 3

    def draw():
        if rng.random() < 0.7:
            return int(rng.integers(lo, cut + 1))
        return int(rng.integers(max(cut + 1, hi - (hi - lo) // 3), hi + 1))

    stream = [sorted(draw() for _ in range(batch_size))
              for _ in range(n_batches)]

    def prompts(lens):
        return [{"tokens": jnp.ones((1, s), jnp.int32)} for s in lens]

    padded, packed = [], []
    real_tokens = 0
    for lens in stream:
        real_tokens += sum(lens)
        bucket = _pow2_at_least(max(lens))
        toks = np.zeros((batch_size, bucket), np.int32)
        for i, s in enumerate(lens):
            toks[i, :s] = 1
        padded.append({"tokens": jnp.asarray(toks)})
        packed.append(eng._pack_prompts(prompts(lens), lens))

    def run_padded():
        out = None
        for b in padded:
            out = eng.prefill(b, cache_len)[0]
        return out

    def run_packed():
        out = None
        for p in packed:
            out = eng.prefill_packed(p)[0]
        return out

    jax.block_until_ready(run_padded())       # warm every bucket
    jax.block_until_ready(run_packed())
    t_pad = _time(lambda: jax.block_until_ready(run_padded()), iters=iters)
    t_pkd = _time(lambda: jax.block_until_ready(run_packed()), iters=iters)
    pad_tokens = sum(b["tokens"].shape[0] * b["tokens"].shape[1]
                     for b in padded)
    rows.append((f"prefill/padded_b{batch_size}", t_pad * 1e6,
                 f"{real_tokens / t_pad:.0f} tok/s "
                 f"({pad_tokens} padded tokens)"))
    rows.append((f"prefill/packed_b{batch_size}", t_pkd * 1e6,
                 f"{real_tokens / t_pkd:.0f} tok/s "
                 f"({sum(p['tokens'].shape[1] for p in packed)} "
                 f"packed tokens)"))
    rows.append(("prefill/packed_speedup_vs_padded", 0.0,
                 f"{t_pad / t_pkd:.2f}x tokens/s"))

    # admission-side dispatch counts: one packed prefill per admission
    # batch (asserted) vs one per request for sequential insert
    def admit_stream(engine, many: bool):
        for lens in stream:
            batch = prompts(lens)
            if many:
                slots = engine.insert_many(batch, n_tokens=[1] * len(lens))
            else:
                slots = [engine.insert(b, n_tokens=1) for b in batch]
            engine.step()
            for slot in slots:
                engine.free(slot)

    seq = make_engine(cfg, cache_len=cache_len).init_slots(
        batch_size, paged=True, page_size=8)
    many = make_engine(cfg, cache_len=cache_len).init_slots(
        batch_size, paged=True, page_size=8)
    admit_stream(seq, many=False)
    admit_stream(many, many=True)
    assert many.stats.packed_prefills == n_batches, (
        many.stats.packed_prefills, n_batches)
    assert many.stats.prefills == n_batches
    fewer = seq.stats.prefills / many.stats.prefills
    rows.append(("prefill/insert_many_dispatches", 0.0,
                 f"{many.stats.prefills} vs {seq.stats.prefills} "
                 f"sequential ({fewer:.1f}x fewer)"))
    return t_pad / t_pkd


def bench_chunked_prefill(rows, *, n_decode, n_burst, cache_len, page_size,
                          decode_prompt, decode_budget, burst_prompt,
                          burst_budget, chunk_tokens, lazy_pages):
    """Chunked prefill (StepPlan API) vs whole-prompt admission on a burst
    of long prompts over in-flight decodes, plus lazy-vs-eager page
    reservation at an equal page budget.

    Section 1 — **time between tokens**: ``n_decode`` requests are
    decoding when ``n_burst`` long prompts arrive at once. Unchunked
    admission prefills the whole burst in one tick (one giant packed
    row), stalling every in-flight decode for that tick; chunked
    admission (``PlannerConfig.chunk_tokens``) spreads the same prefill
    tokens across ticks interleaved with decodes. Reported: p99 of the
    per-tick wall time over ticks that emitted decode tokens — the
    time-between-tokens a decoding client observes. Both paths produce
    bit-identical token streams (asserted here; per-family proofs in
    tests/test_plan.py).

    Section 2 — **lazy reservation + preemption**: the same mixed-budget
    stream served at an equal page budget with up-front prompt+budget
    reservation vs lazy prompt-only reservation (grow per decode step,
    preempt-and-requeue on OutOfPages). Lazy admits strictly more
    resident sequences; the preemption/requeue counters must be
    exercised (CI gate) and the streams must still match."""
    import numpy as np
    from repro.configs import get_config
    from repro.serving.engine import make_engine
    from repro.serving.metrics import percentile
    from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
    from repro.serving.request import Request, RequestQueue

    cfg = get_config("olmo-1b").reduced()
    name = cfg.name
    n_slots = n_decode + n_burst

    def workload():
        reqs, prompts = [], {}
        for i in range(n_decode):
            reqs.append(Request(arrival=0.0, rid=i, model=name, slo=1e9,
                                n_tokens=decode_budget,
                                prompt_len=decode_prompt))
        for j in range(n_burst):
            # the burst lands after the decodes settle in
            reqs.append(Request(arrival=5e-3, rid=n_decode + j, model=name,
                                slo=1e9, n_tokens=burst_budget,
                                prompt_len=burst_prompt))
        for r in reqs:
            prompts[r.rid] = {"tokens": jnp.ones((1, r.prompt_len),
                                                 jnp.int32)}
        return reqs, prompts

    def serve(eng, chunk, lazy=False):
        eng.release_all_slots()
        eng.reset_stats()
        reqs, prompts = workload()
        planner = StepPlanner(eng, RequestQueue(name, slo=1e9),
                              PlannerConfig(chunk_tokens=chunk, lazy=lazy,
                                            gen_len=4))
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
        assert not srv.truncated
        streams = {r: tuple(t) for r, t in planner.streams.items()}
        gaps = [w for w, ntok in srv.tick_walls if ntok > 0]
        return streams, gaps, planner, srv

    results = {}
    eng = make_engine(cfg, cache_len=cache_len).init_slots(
        n_slots, paged=True, page_size=page_size)
    for label, chunk in (("unchunked", 0), ("chunked", chunk_tokens)):
        serve(eng, chunk)                       # warm every executable
        # p99 here is a STRUCTURAL quantity (the prefill-stall tick);
        # take the min over repeats so host-noise spikes on a shared CPU
        # can't masquerade as structure
        p99s, p50s = [], []
        for _ in range(3):
            streams, gaps, planner, srv = serve(eng, chunk)
            p99s.append(percentile(gaps, 0.99))
            p50s.append(percentile(gaps, 0.5))
        # worst prefill work co-scheduled with a decode tick — the
        # deterministic quantity chunking bounds (wall p99 is its noisy
        # wall-clock counterpart on a shared host)
        stall = max(p for p, (_, ntok) in zip(srv.tick_prefill,
                                              srv.tick_walls) if ntok)
        results[label] = (streams, min(p99s), stall)
        rows.append((f"serve/{label}_tbt_p99", min(p99s) * 1e6,
                     f"p50={sorted(p50s)[1] * 1e6:.0f}us over "
                     f"{len(gaps)} decode ticks ({srv.ticks} ticks, "
                     f"{srv.dispatches} dispatches; min of 3 runs)"))
        # TTFT in virtual tick time (always-on RequestQueue recording):
        # chunking trades burst-prompt TTFT for decode TBT — both now
        # visible (deterministic, so no min-over-repeats needed)
        q = planner.queue
        rows.append((f"serve/{label}_ttft_p50",
                     percentile(q.ttfts, 0.5) * 1e6,
                     f"p99={percentile(q.ttfts, 0.99) * 1e6:.0f}us "
                     f"virtual (n={len(q.ttfts)}, "
                     f"tbt_p50={percentile(q.tbts, 0.5) * 1e6:.1f}us)"))
    assert results["chunked"][0] == results["unchunked"][0], \
        "chunked prefill diverged from whole-prompt admission"
    _, p99_u, stall_u = results["unchunked"]
    _, p99_c, stall_c = results["chunked"]
    rows.append(("serve/chunked_tbt_p99_speedup", 0.0,
                 f"{p99_u / p99_c:.2f}x lower time-between-tokens p99 "
                 f"(burst of {n_burst}x{burst_prompt}-token prompts over "
                 f"{n_decode} in-flight decodes, chunk={chunk_tokens})"))
    rows.append(("serve/chunked_worst_tick_prefill_tokens", 0.0,
                 f"{stall_c} vs {stall_u} unchunked "
                 f"({stall_u / max(1, stall_c):.1f}x less prefill work "
                 f"co-scheduled with the worst decode tick)"))
    # deterministic CI gate: chunking must strictly bound the prefill
    # work any decode tick can be stalled behind
    assert stall_c < stall_u, (stall_c, stall_u)

    # ---- lazy reservation + preemption at an equal page budget
    lazy_results = {}
    eng2 = make_engine(cfg, cache_len=cache_len).init_slots(
        n_slots, paged=True, page_size=page_size, total_pages=lazy_pages)
    for mode, lazy in (("eager", False), ("lazy", True)):
        serve(eng2, chunk_tokens, lazy=lazy)    # warm (incl. grow path)
        streams, _, planner, srv = serve(eng2, chunk_tokens, lazy=lazy)
        lazy_results[mode] = (streams, planner, srv)
        rows.append((f"serve/{mode}_reservation_peak_resident", 0.0,
                     f"{srv.peak_resident} resident seqs at "
                     f"{lazy_pages} pages "
                     f"(preempt={planner.metrics.preemptions} "
                     f"requeue={planner.metrics.requeues})"))
    (s_e, p_e, srv_e) = lazy_results["eager"]
    (s_l, p_l, srv_l) = lazy_results["lazy"]
    assert s_l == s_e, "lazy/preempted serving diverged from eager"
    assert srv_l.peak_resident > srv_e.peak_resident, \
        "lazy reservation did not admit more residents"
    # the CI gate from the issue: the preempt-and-requeue path must
    # actually run in quick mode, not just exist
    assert p_l.metrics.preemptions > 0 and p_l.metrics.requeues > 0, \
        "lazy serving never exercised preemption/requeue"
    rows.append(("serve/lazy_resident_gain", 0.0,
                 f"{srv_l.peak_resident}/{srv_e.peak_resident} resident "
                 f"seqs lazy vs up-front at {lazy_pages} pages"))
    return p99_u / p99_c


def bench_shared_prefix(rows, *, prefix_lens, group_probs, n_requests,
                        gen_len, cache_len, page_size, n_slots,
                        tight_pages):
    """Radix prompt cache (``--shared-prefix``) on a heavy-tailed
    shared-prefix request stream: a few prompt "templates" (system
    prompts / few-shot preambles) with popularity skew, each request
    appending a short random tail.

    Section 1 — **prefill tokens saved**: the same stream served with
    the prefix cache off and on, on one engine with ample pages. Cache
    hits alias the template's KV pages into the new request's block
    table and teacher-force the uncovered tail, so admission prefill
    tokens dispatched must drop ≥40% (CI gate) while the greedy token
    streams stay bit-identical (asserted). The warmed executables must
    be reused as-is: zero recompiles across both modes (asserted).

    Section 2 — **resident sequences gained**: the same stream at a
    TIGHT page budget. Aliased pages are refcounted, not copied, so
    popular prefixes are resident once instead of once per request and
    strictly more sequences fit at the same page budget (asserted);
    cold radix nodes are evicted before any resident is preempted."""
    import dataclasses

    import numpy as np
    from repro.configs import get_config
    from repro.serving.engine import make_engine
    from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
    from repro.serving.request import Request, RequestQueue

    cfg = get_config("olmo-1b").reduced()
    name = cfg.name
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
                for s in prefix_lens]

    reqs, prompts = [], {}
    shared_tokens = total_tokens = 0
    for i in range(n_requests):
        g = int(rng.choice(len(prefix_lens), p=list(group_probs)))
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(2, 7))).astype(np.int32)
        toks = np.concatenate([prefixes[g], tail])
        shared_tokens += len(prefixes[g])
        total_tokens += len(toks)
        reqs.append(Request(arrival=0.0, rid=i, model=name, slo=1e9,
                            n_tokens=gen_len, prompt_len=len(toks)))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}
    # the regime the cache targets: most prompt tokens are template
    assert shared_tokens >= total_tokens // 2, (shared_tokens, total_tokens)

    def serve(eng, prefix_on):
        eng.release_all_slots()          # frees slots AND flushes the cache
        eng.reset_stats()
        planner = StepPlanner(eng, RequestQueue(name, slo=1e9),
                              PlannerConfig(gen_len=gen_len,
                                            prefix_cache=prefix_on))
        t0 = time.perf_counter()
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
        wall = time.perf_counter() - t0
        assert not srv.truncated
        eng.check_page_invariants()
        streams = {r: tuple(t) for r, t in planner.streams.items()}
        return streams, dataclasses.replace(eng.stats), srv, wall

    # ---- section 1: prefill tokens saved at an ample page budget
    eng = make_engine(cfg, cache_len=cache_len).init_slots(
        n_slots, paged=True, page_size=page_size)
    eng.enable_prefix_cache()
    eng.warm_prefix_ops()
    for on in (False, True):
        serve(eng, on)                   # warm every executable both modes
    jit0 = eng.jit_cache_sizes()
    base, st_off, srv_off, w_off = serve(eng, False)
    got, st_on, srv_on, w_on = serve(eng, True)
    assert eng.jit_cache_sizes() == jit0, \
        "prefix cache caused recompiles after warmup"
    assert got == base, "prefix-cache serving diverged from cache-off"
    pf_off, pf_on = st_off.prefill_tokens, st_on.prefill_tokens
    assert pf_on <= 0.6 * pf_off, \
        f"prefill tokens only dropped {pf_off} -> {pf_on} (<40%)"
    toks = sum(r.n_tokens for r in reqs)
    rows.append(("serve/shared_prefix_off_prefill_tokens", w_off * 1e6,
                 f"{pf_off} prompt tokens prefetched, "
                 f"{toks / w_off:.0f} gen tok/s"))
    rows.append(("serve/shared_prefix_on_prefill_tokens", w_on * 1e6,
                 f"{pf_on} prompt tokens prefetched, "
                 f"{toks / w_on:.0f} gen tok/s"))
    rows.append(("serve/shared_prefix_tokens_saved", 0.0,
                 f"{1 - pf_on / pf_off:.0%} fewer prefill tokens "
                 f"({pf_off} -> {pf_on}; {st_on.prefix_hits} hits, "
                 f"{st_on.prefix_hit_tokens} aliased tokens, "
                 f"{st_on.cow_copies} COW copies, "
                 f"{st_on.forced_catchup_tokens} teacher-forced)"))

    # ---- section 2: resident sequences gained at a tight page budget.
    # Surplus slot rows (cheap bookkeeping) so the PAGE budget, not the
    # slot count, gates admission — same setup as the ring-vs-paged bench.
    eng2 = make_engine(cfg, cache_len=cache_len).init_slots(
        4 * n_slots, paged=True, page_size=page_size,
        total_pages=tight_pages)
    eng2.enable_prefix_cache()
    eng2.warm_prefix_ops()
    for on in (False, True):
        serve(eng2, on)
    base2, _, srv2_off, _ = serve(eng2, False)
    got2, st2_on, srv2_on, _ = serve(eng2, True)
    assert got2 == base2, "tight-budget prefix serving diverged"
    assert srv2_on.peak_resident > srv2_off.peak_resident, (
        srv2_on.peak_resident, srv2_off.peak_resident)
    rows.append(("serve/shared_prefix_resident_gain", 0.0,
                 f"{srv2_on.peak_resident}/{srv2_off.peak_resident} "
                 f"resident seqs at {tight_pages} pages "
                 f"({st2_on.prefix_hits} hits, "
                 f"{st2_on.cow_copies} COW copies)"))
    return pf_off / max(1, pf_on)


def bench_speculative(rows, *, n_requests, prompt_len, gen_len, cache_len,
                      page_size, n_slots, spec_k, iters,
                      check_speedup=True):
    """Speculative decoding (``--speculative``): an identical-weights
    draft twin proposes ``spec_k`` tokens per decoding slot per tick in
    ONE fused scan dispatch, and the target verifies every slot's chunk
    in ONE packed incremental chunk-attention dispatch — so a tick that
    plain greedy decoding spends emitting 1 token/slot emits up to
    ``spec_k + 1`` tokens/slot for 2 dispatches. Identical weights make
    acceptance exactly 1.0 (the draft IS the target), isolating the
    protocol + dispatch-count win from draft quality; tokens/s must
    improve >1.5x (CI gate, ``check_speedup``), the greedy streams must
    be bit-exact with the non-speculative serve, and zero executables
    may compile between warmed serves.

    Speculation converts per-token dispatch + host overhead into
    per-round overhead; with an equal-cost draft the model FLOPs are
    unchanged, so the wall win exists exactly where decode is
    dispatch-bound — the regime GPU decode serving lives in (tiny
    per-step kernels, fixed launch/host cost; the paper's premise).
    The XLA-CPU harness is compute-bound at the reduced config (a
    decode step's math costs ~5x its dispatch), which NO equal-cost
    draft can beat, so this bench shrinks the twin until per-step math
    is small against dispatch overhead and the clock measures the
    protocol, not the backend's GEMM throughput. The dispatch-count
    reduction column is deterministic and backend-independent.

    The speedup gate compares ``time.process_time`` (min over
    ``iters``): CI runs on contended shared-vCPU hosts where wall
    clock carries scheduler steal that can double a serve at random,
    while process CPU time is steal-free and both serves are
    single-stream host-bound loops, so their CPU-time ratio IS the
    quiet-host tokens/s ratio. Wall tok/s is still reported per row."""
    import dataclasses

    import numpy as np
    from repro.configs import get_config
    from repro.serving.engine import InferenceEngine, make_engine
    from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
    from repro.serving.request import Request, RequestQueue

    cfg = dataclasses.replace(
        get_config("olmo-1b").reduced(), num_layers=1, d_model=64,
        d_ff=128, num_heads=1, num_kv_heads=1, head_dim=64)
    name = cfg.name
    eng = make_engine(cfg, cache_len=cache_len).init_slots(
        n_slots, paged=True, page_size=page_size)
    draft = InferenceEngine(eng.api, eng.params,
                            cache_len=cache_len).init_slots(
        n_slots, paged=False)
    eng.attach_draft(draft, spec_k=spec_k)

    rng = np.random.default_rng(0)
    reqs, prompts = [], {}
    for i in range(n_requests):
        toks = rng.integers(1, cfg.vocab_size,
                            size=prompt_len).astype(np.int32)
        reqs.append(Request(arrival=0.0, rid=i, model=name, slo=1e9,
                            n_tokens=gen_len, prompt_len=prompt_len))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}

    def serve(spec: bool):
        eng.release_all_slots()
        eng.reset_stats()
        draft.reset_stats()
        planner = StepPlanner(eng, RequestQueue(name, slo=1e9),
                              PlannerConfig(gen_len=gen_len,
                                            spec_k=spec_k if spec else 0))
        t0, c0 = time.perf_counter(), time.process_time()
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
        wall = time.perf_counter() - t0
        cpu = time.process_time() - c0
        assert not srv.truncated
        eng.check_page_invariants()
        streams = {r: tuple(t) for r, t in planner.streams.items()}
        return streams, dataclasses.replace(eng.stats), wall, cpu

    for spec in (False, True):
        serve(spec)                     # warm every executable both modes
    jit0 = eng.jit_cache_sizes()
    walls = {False: [], True: []}
    cpus = {False: [], True: []}
    for _ in range(iters):
        base, st_off, w, c = serve(False)
        walls[False].append(w)
        cpus[False].append(c)
        got, st_on, w, c = serve(True)
        walls[True].append(w)
        cpus[True].append(c)
    assert eng.jit_cache_sizes() == jit0, \
        "speculative serving compiled after warmup"
    assert got == base, "speculative streams diverged from plain greedy"
    assert st_on.draft_tokens > 0 and st_on.spec_rounds > 0
    accept = st_on.accepted_tokens / st_on.draft_tokens
    assert accept == 1.0, f"identical-weights draft rejected: {accept}"
    toks = sum(len(t) for t in base.values())
    w_off, w_on = min(walls[False]), min(walls[True])
    speedup = min(cpus[False]) / min(cpus[True])
    # dispatch counts are DETERMINISTIC: plain greedy pays one decode
    # dispatch per tick; a speculative tick pays a draft scan + a packed
    # verify (2) for up to spec_k+1 tokens per slot
    d_off = st_off.decode_steps
    d_on = st_on.decode_steps + 2 * st_on.spec_rounds
    rows.append(("serve/speculative_off_tok_s", w_off * 1e6,
                 f"{toks / w_off:.0f} tok/s "
                 f"({d_off} decode dispatches; min of {iters})"))
    rows.append(("serve/speculative_on_tok_s", w_on * 1e6,
                 f"{toks / w_on:.0f} tok/s ({st_on.spec_rounds} spec "
                 f"rounds + {st_on.decode_steps} decodes = {d_on} "
                 f"dispatches; min of {iters})"))
    rows.append(("serve/speculative_acceptance", 0.0,
                 f"{accept:.2f} ({st_on.accepted_tokens}/"
                 f"{st_on.draft_tokens} draft tokens accepted, "
                 f"{st_on.rollbacks} rollbacks, k={spec_k})"))
    rows.append(("serve/speculative_dispatch_reduction", 0.0,
                 f"{d_off}/{d_on} decode-path dispatches "
                 f"({d_off / max(1, d_on):.1f}x fewer)"))
    rows.append(("serve/speculative_speedup", 0.0,
                 f"{speedup:.2f}x decode tokens/s (cpu-time; wall "
                 f"{w_off / w_on:.2f}x)"))
    assert d_off / max(1, d_on) > 1.5, (d_off, d_on)
    if check_speedup:
        assert speedup > 1.5, \
            f"speculative speedup {speedup:.2f}x <= 1.5x"
    return speedup


def run(quick: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        bench_generate(rows, batch_size=2, gen_tokens=4, iters=1,
                       prompt_lens=(8, 16), base_cache=8)
        bench_ragged(rows, cache_len=256, block_k=64, iters=1)
    elif quick:
        bench_generate(rows, batch_size=8, gen_tokens=16, iters=2)
        bench_ragged(rows, cache_len=4096, block_k=512, iters=5)
    else:
        bench_generate(rows, batch_size=8, gen_tokens=32, iters=3,
                       prompt_lens=(24, 40, 56, 72, 96, 128))
        bench_ragged(rows, cache_len=8192, block_k=512, iters=5)
    rows.extend(run_paged(quick=quick, smoke=smoke))
    rows.extend(run_packed_prefill(quick=quick, smoke=smoke))
    rows.extend(run_chunked_prefill(quick=quick, smoke=smoke))
    rows.extend(run_shared_prefix(quick=quick, smoke=smoke))
    rows.extend(run_speculative(quick=quick, smoke=smoke))
    return rows


def run_paged(quick: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        bench_paged(rows, n_slots=2, cache_len=32, page_size=8,
                    n_requests=8, gen_range=(2, 7), iters=1)
    elif quick:
        bench_paged(rows, n_slots=4, cache_len=64, page_size=8,
                    n_requests=48, gen_range=(4, 40), iters=2)
    else:
        bench_paged(rows, n_slots=8, cache_len=128, page_size=8,
                    n_requests=128, gen_range=(4, 96), iters=3)
    return rows


def run_packed_prefill(quick: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        bench_packed_prefill(rows, batch_size=4, cache_len=32,
                             len_range=(4, 24), n_batches=2, iters=1)
    elif quick:
        bench_packed_prefill(rows, batch_size=8, cache_len=128,
                             len_range=(16, 120), n_batches=6, iters=3)
    else:
        bench_packed_prefill(rows, batch_size=16, cache_len=256,
                             len_range=(16, 248), n_batches=8, iters=3)
    return rows


def run_chunked_prefill(quick: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        bench_chunked_prefill(rows, n_decode=2, n_burst=2, cache_len=64,
                              page_size=8, decode_prompt=4,
                              decode_budget=28, burst_prompt=40,
                              burst_budget=4, chunk_tokens=8,
                              lazy_pages=8)
    elif quick:
        bench_chunked_prefill(rows, n_decode=4, n_burst=8, cache_len=128,
                              page_size=8, decode_prompt=4,
                              decode_budget=48, burst_prompt=120,
                              burst_budget=4, chunk_tokens=64,
                              lazy_pages=40)
    else:
        bench_chunked_prefill(rows, n_decode=8, n_burst=6, cache_len=256,
                              page_size=8, decode_prompt=8,
                              decode_budget=96, burst_prompt=224,
                              burst_budget=8, chunk_tokens=64,
                              lazy_pages=64)
    return rows


def run_shared_prefix(quick: bool = True, smoke: bool = False):
    rows = []
    # template lengths deliberately include non-multiples of the page
    # size so some hits diverge mid-page and exercise the COW copy
    if smoke:
        bench_shared_prefix(rows, prefix_lens=(20, 8),
                            group_probs=(0.7, 0.3), n_requests=16,
                            gen_len=3, cache_len=32, page_size=8,
                            n_slots=4, tight_pages=10)
    elif quick:
        bench_shared_prefix(rows, prefix_lens=(40, 28, 16),
                            group_probs=(0.6, 0.3, 0.1), n_requests=24,
                            gen_len=4, cache_len=64, page_size=8,
                            n_slots=4, tight_pages=20)
    else:
        bench_shared_prefix(rows, prefix_lens=(96, 52, 24),
                            group_probs=(0.6, 0.3, 0.1), n_requests=48,
                            gen_len=8, cache_len=128, page_size=8,
                            n_slots=8, tight_pages=40)
    return rows


def run_speculative(quick: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        # tiny shapes: wall ratio is host noise, so only the protocol
        # invariants and the deterministic dispatch reduction gate
        bench_speculative(rows, n_requests=4, prompt_len=4, gen_len=10,
                          cache_len=32, page_size=8, n_slots=2, spec_k=7,
                          iters=1, check_speedup=False)
    elif quick:
        # few slots + long gen: plain batch decode amortizes its one
        # dispatch across slots, so wide batches flatter the baseline;
        # long generations amortize the one-time draft admission
        bench_speculative(rows, n_requests=4, prompt_len=8, gen_len=120,
                          cache_len=128, page_size=8, n_slots=2, spec_k=7,
                          iters=4)
    else:
        bench_speculative(rows, n_requests=8, prompt_len=8, gen_len=160,
                          cache_len=192, page_size=8, n_slots=2, spec_k=7,
                          iters=4)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iter (CI import-and-run check)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="ring vs paged KV slots on a mixed-length stream")
    ap.add_argument("--packed-prefill", action="store_true",
                    help="packed ragged prefill vs pad-to-max on a "
                         "mixed-length prompt stream")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="StepPlan chunked prefill vs whole-prompt "
                         "admission (time-between-tokens p99) + lazy "
                         "page reservation vs up-front (preemption)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="radix prompt cache on a heavy-tailed "
                         "shared-prefix stream: prefill tokens saved + "
                         "resident sequences gained at a tight page "
                         "budget (bit-exact, zero recompiles)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative decoding with an identical-weights "
                         "draft twin: >1.5x decode tokens/s via fused "
                         "draft scan + one packed verify dispatch per "
                         "tick (bit-exact streams, 0 recompiles)")
    ap.add_argument("--json", nargs="?", const="BENCH_decode.json",
                    default=None, metavar="PATH", dest="json_out",
                    help="write rows as dstack-bench-v1 JSON (shared "
                         "schema with bench_pool; default "
                         "BENCH_decode.json)")
    args = ap.parse_args()
    fn, section = run, "all"
    if args.paged:
        fn, section = run_paged, "paged"
    elif args.packed_prefill:
        fn, section = run_packed_prefill, "packed_prefill"
    elif args.chunked_prefill:
        fn, section = run_chunked_prefill, "chunked_prefill"
    elif args.shared_prefix:
        fn, section = run_shared_prefix, "shared_prefix"
    elif args.speculative:
        fn, section = run_speculative, "speculative"
    rows = fn(quick=not args.full, smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if args.json_out:
        try:                      # package context (benchmarks/run.py)
            from benchmarks import common as _common
        except ImportError:       # script context
            import common as _common
        payload = _common.bench_payload(
            "bench_decode", rows,
            args={"quick": not args.full, "smoke": args.smoke,
                  "section": section})
        _common.write_json(args.json_out, payload)
        print(f"wrote {args.json_out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
