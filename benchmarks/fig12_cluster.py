"""Paper Fig. 12: multi-pod cluster throughput — exclusive pod-per-model vs
temporal-per-pod vs D-STACK-per-pod."""
from __future__ import annotations

from benchmarks.common import C4, generators_for, profiles_for, timed
from repro.core.cluster import run_cluster


def run(quick: bool = True):
    dur = 1.0 if quick else 10.0
    rate = 20_000        # saturating: per-pod capacity is the bottleneck
    rows = []
    thr = {}
    for mode in ("exclusive", "temporal", "dstack"):
        profiles = profiles_for(C4, rate=rate)
        gens = generators_for(profiles, rate)
        cr, us = timed(run_cluster, profiles, gens, mode=mode, n_pods=4,
                       duration=dur)
        thr[mode] = cr.total_throughput
        rows.append((f"fig12/{mode}/cluster_throughput", us,
                     f"{cr.total_throughput:.0f}"))
        rows.append((f"fig12/{mode}/utilization", 0.0,
                     f"{cr.utilization:.3f}"))
    rows.append(("fig12/dstack_over_temporal_pct", 0.0,
                 f"{100*(thr['dstack']/thr['temporal']-1):.0f}"))
    return rows
