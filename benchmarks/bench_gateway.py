"""Gateway traffic benchmark: admission policies under production traffic.

The ISSUE 10 acceptance run: one seeded burst + mixed-SLO trace
(``repro.serving.traffic``) is served through the async gateway
(``repro.serving.gateway``) under two admission policies over the SAME
warmed engine —

  temporal   strict FIFO admission (tiers off — every pre-PR-10 plane)
  dstack     weighted tiers + per-tenant deficit fairness
             (``PlannerConfig.tiers``)

— and the bench reports goodput, per-tier SLO attainment, per-tenant
Jain fairness, and shed/abort rates for each. The trace floods one
tenant's batch-tier work mid-run, so under FIFO the flood queues ahead
of every later interactive arrival; under tiers it cannot. The quick
pass ASSERTS the acceptance criteria: tiered interactive-tier
attainment strictly above FIFO's at equal offered load, per-tenant Jain
no worse, and zero recompiles across the measured virtual runs (the
wall pass may trace a bounded handful of first-seen prefill packings —
host pacing decides how prefills pack — never the decode path).

A wall-clock pass then re-serves the same trace with
``AsyncGateway(wall_clock=True)`` and PR 7's ``StepTimers`` attached
(SLOs relaxed — CPU-host ticks run an order of magnitude slower than
the 1ms virtual tick, so real-time deadlines would reject the trace):
streams must stay BIT-EXACT with the virtual-clock run, and the
roofline report joins measured per-dispatch wall clock against the
latency-model predictions (deviations are flagged, not fatal — on a CPU
host essentially every row flags, which is the signal).

CLI: ``PYTHONPATH=src python benchmarks/bench_gateway.py [--quick|--full]
[--json [PATH]]``; also wired into ``benchmarks/run.py`` as
``bench_gateway``.
"""
from __future__ import annotations

import time

try:                      # package context (benchmarks/run.py)
    from benchmarks import common as _common
except ImportError:       # script context (python benchmarks/bench_gateway.py)
    import common as _common

MODEL = "olmo-1b"
CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8


def _build_engine():
    from repro.configs import get_config
    from repro.serving.engine import make_engine

    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    eng.alloc_chips = 1                   # roofline rows need a chip count
    return cfg, eng


def _burst_cfg(quick: bool):
    from repro.serving.traffic import TrafficConfig

    return TrafficConfig(
        model=MODEL,
        duration=0.2 if quick else 0.6,
        rate=240.0,
        seed=12,
        slo_unit=1e-3,                    # calibrated to the 1ms tick
        prompt_tokens=(4, 12),
        gen_tokens=(3, 8))


def _reset_trace(reqs):
    for r in reqs:
        r.state = "pending"
        r.finish = -1.0
        r.first_token = -1.0
        r.tokens_out = 0


def _serve(cfg, eng, reqs, prompts, *, tiers=None, wall=False,
           telemetry=None):
    """One gateway serve of the trace; returns (streams, planner, gw,
    wall seconds)."""
    from repro.serving.gateway import AsyncGateway
    from repro.serving.plan import PlannerConfig, StepPlanner
    from repro.serving.request import RequestQueue

    _reset_trace(reqs)
    eng.release_all_slots()
    eng.reset_stats()
    planner = StepPlanner(eng, RequestQueue(cfg.name, slo=1e9),
                          PlannerConfig(gen_len=4, tiers=tiers))
    planner.telemetry = telemetry
    gw = AsyncGateway(planner, wall_clock=wall, stall_limit=100)
    t0 = time.perf_counter()
    streams = gw.serve_trace(reqs, prompts)
    wall_s = time.perf_counter() - t0
    assert not gw.truncated, "gateway serve hit the max_ticks backstop"
    assert eng.free_pages == eng.total_pages, "gateway serve leaked pages"
    return streams, planner, gw, wall_s


def _wall_serve(cfg, eng, reqs, prompts, *, tiers, telemetry=None):
    """Wall-clock serve with SLOs relaxed: a CPU-host tick takes
    ~10-30ms real against the 1ms virtual tick, so real-time deadlines
    would reject nearly every request the virtual run admitted — the
    wall pass validates pacing, timers and streams, not attainment."""
    slos = [r.slo for r in reqs]
    for r in reqs:
        r.slo = 1e9
    try:
        return _serve(cfg, eng, reqs, prompts, tiers=tiers, wall=True,
                      telemetry=telemetry)
    finally:
        for r, slo in zip(reqs, slos):
            r.slo = slo


def _score(reqs, planner, gw):
    """Per-policy scorecard over the trace's stamped outcomes."""
    from repro.serving.traffic import attainment_by, offered_by

    q = planner.queue
    ontime = sum(1 for r in reqs
                 if r.state == "completed" and 0 <= r.finish <= r.deadline)
    horizon = max(gw.now, 1e-9)
    return {
        "goodput_rps": ontime / horizon,
        "attainment_by_tier": attainment_by(reqs, "tier"),
        "attainment_by_tenant": attainment_by(reqs, "tenant"),
        "offered_by_tier": offered_by(reqs, "tier"),
        "tenant_jain": planner.metrics.tenant_fairness(),
        "completed": q.completed,
        "shed": q.shed,
        "dropped": q.dropped,
        "deadline_aborted": q.deadline_aborted,
        "late": q.late,
        "ticks": gw.server.ticks,
    }


def run_with_results(quick: bool = True):
    """Serve the burst trace under both policies plus the wall-clock
    pass; returns (rows, {policy: score}, roofline rows)."""
    from repro.core.profiles import build_profile
    from repro.serving.telemetry import Telemetry, roofline_report
    from repro.serving.traffic import (TIER_WEIGHTS, burst_trace,
                                       offered_by, synth_prompts)

    cfg, eng = _build_engine()
    tcfg = _burst_cfg(quick)
    reqs = burst_trace(tcfg, burst_mult=16.0)
    prompts = synth_prompts(reqs, vocab=cfg.vocab_size, seed=0)
    offered = offered_by(reqs, "tier")
    t0 = time.time()
    rows = [("gateway/trace", 0.0,
             f"burst x16, {len(reqs)} requests over {tcfg.duration}s "
             f"virtual ({' '.join(f'{k}={v}' for k, v in sorted(offered.items()))})")]

    policies = [("temporal", None), ("dstack", dict(TIER_WEIGHTS))]
    # warm every executable both admission orders reach — plus a
    # wall-clock pass, whose host-paced arrival floods produce batch
    # shapes the virtual passes never form — then freeze
    for _, tiers in policies:
        _serve(cfg, eng, reqs, prompts, tiers=tiers)
    _wall_serve(cfg, eng, reqs, prompts, tiers=dict(TIER_WEIGHTS))
    rows.append(("gateway/build_warm_s", (time.time() - t0) * 1e6,
                 f"engine + both policy passes warmed"))
    jit_before = eng.jit_cache_sizes()

    scores = {}
    streams_by_policy = {}
    for name, tiers in policies:
        streams, planner, gw, wall_s = _serve(cfg, eng, reqs, prompts,
                                              tiers=tiers)
        s = _score(reqs, planner, gw)
        scores[name] = s
        streams_by_policy[name] = {r: tuple(st.tokens)
                                   for r, st in streams.items()}
        att = s["attainment_by_tier"]
        rows.append((f"gateway/{name}/goodput", wall_s * 1e6,
                     f"{s['goodput_rps']:.1f} ontime req/s virtual "
                     f"({s['completed']} completed, {s['late']} late)"))
        rows.append((f"gateway/{name}/attainment", 0.0,
                     " ".join(f"{t}={att.get(t, 0.0):.3f}"
                              for t in ("interactive", "standard", "batch"))))
        rows.append((f"gateway/{name}/tenant_jain", 0.0,
                     f"{s['tenant_jain']:.4f}"))
        rows.append((f"gateway/{name}/shed_abort", 0.0,
                     f"shed={s['shed']} dropped={s['dropped']} "
                     f"aborted={s['deadline_aborted']}"))
    assert eng.jit_cache_sizes() == jit_before, \
        "measured policy runs recompiled"

    # acceptance: tiers rescue interactive attainment at equal offered
    # load without degrading per-tenant fairness
    fifo, tiered = scores["temporal"], scores["dstack"]
    int_fifo = fifo["attainment_by_tier"].get("interactive", 0.0)
    int_tiered = tiered["attainment_by_tier"].get("interactive", 0.0)
    assert int_tiered > int_fifo, (
        f"tiered admission did not beat FIFO on interactive attainment "
        f"({int_tiered:.3f} vs {int_fifo:.3f})")
    assert tiered["tenant_jain"] >= fifo["tenant_jain"] - 1e-9, (
        f"tiered admission degraded tenant fairness "
        f"({tiered['tenant_jain']:.4f} vs {fifo['tenant_jain']:.4f})")
    rows.append(("gateway/acceptance", 0.0,
                 f"interactive {int_fifo:.3f}->{int_tiered:.3f}, "
                 f"jain {fifo['tenant_jain']:.4f}->"
                 f"{tiered['tenant_jain']:.4f}"))

    # wall-clock pass: same trace, host-paced ticks, StepTimers attached
    # behind block-until-ready; streams must not move by a bit
    # (deadlines relaxed inside _wall_serve — see its docstring)
    tel = Telemetry()                     # timers only, no trace
    eng.attach_telemetry(tel)
    try:
        streams, planner, gw, wall_s = _wall_serve(
            cfg, eng, reqs, prompts, tiers=dict(TIER_WEIGHTS),
            telemetry=tel)
    finally:
        eng.attach_telemetry(None)
    got = {r: tuple(st.tokens) for r, st in streams.items()}
    assert got == streams_by_policy["dstack"], \
        "wall-clock serve diverged from virtual-clock serve"
    # host pacing decides how prefills pack, so the wall pass may trace
    # a handful of first-seen packed-prefill shapes; the steady-state
    # decode path must stay frozen and growth must stay O(shapes), not
    # O(requests)
    jit_after = eng.jit_cache_sizes()
    grown = {k: jit_after[k] - jit_before.get(k, 0)
             for k in jit_after if jit_after[k] != jit_before.get(k, 0)}
    assert set(grown) <= {"packed_prefill", "write_segments"}, \
        f"wall-clock pass recompiled the decode path: {grown}"
    assert sum(grown.values()) <= 6, \
        f"wall-clock pass recompilation not shape-bounded: {grown}"
    report = roofline_report(
        tel.timers, {cfg.name: build_profile(MODEL, request_rate=1000.0)})
    assert report, "wall-clock pass timed no dispatches"
    flagged = sum(1 for r in report if r.flagged)
    rows.append(("gateway/wall_clock/bit_exact", wall_s * 1e6,
                 f"{gw.server.ticks} ticks host-paced, streams identical "
                 f"to virtual"))
    rows.append(("gateway/wall_clock/roofline_rows", 0.0,
                 f"{len(report)} rows, {flagged} flagged at 4x tol "
                 f"(CPU host vs TPU rooflines — deviations are the "
                 f"signal)"))
    rows.append(("gateway/recompilations", 0.0,
                 f"0 measured; wall pass traced "
                 f"{sum(grown.values())} first-seen prefill packings"))
    return rows, scores, report


def run_scenarios(quick: bool = True):
    """Seeded scenario census: every generator, deterministic shape."""
    from repro.serving.traffic import (SCENARIOS, TrafficConfig,
                                       make_scenario, offered_by)

    rows = []
    cfg = TrafficConfig(model=MODEL, duration=0.5 if quick else 2.0,
                        rate=120.0, seed=7)
    for name in sorted(SCENARIOS):
        a = make_scenario(name, cfg)
        b = make_scenario(name, cfg)
        assert [(r.arrival, r.rid) for r in a] \
            == [(r.arrival, r.rid) for r in b], f"{name} not deterministic"
        tiers = offered_by(a, "tier")
        rows.append((f"gateway/scenario/{name}", 0.0,
                     f"{len(a)} arrivals "
                     + " ".join(f"{k}={v}"
                                for k, v in sorted(tiers.items()))))
    return rows


def run(quick: bool = True):
    """``benchmarks/run.py`` entry point — CSV rows only."""
    rows, _, _ = run_with_results(quick)
    return rows + run_scenarios(quick)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized pass (default)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", nargs="?", const="BENCH_gateway.json",
                    default=None, metavar="PATH", dest="json_out",
                    help="write rows + per-policy scorecards + roofline "
                         "report as dstack-bench-v1 JSON (default "
                         "BENCH_gateway.json)")
    args = ap.parse_args()
    quick = not args.full
    rows, scores, report = run_with_results(quick)
    rows += run_scenarios(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    print()
    from repro.serving.telemetry import format_roofline
    print("roofline validation (measured wall-clock vs latency_model)")
    for line in format_roofline(report):
        print(line)
    if args.json_out:
        payload = _common.bench_payload(
            "bench_gateway", rows,
            args={"quick": quick},
            extra={"scores": scores,
                   "roofline": [r.as_dict() for r in report]})
        _common.write_json(args.json_out, payload)
        print(f"wrote {args.json_out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
