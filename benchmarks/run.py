"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` runs paper-scale
durations; default is the quick CI-sized pass.
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    "table1_completion",
    "fig2_knee",
    "fig4_analytic",
    "fig7_efficacy",
    "fig9_schedulers",
    "fig10_fairness",
    "fig11_multiplex",
    "fig12_cluster",
    "roofline",
    "kernels_micro",
    "bench_decode",
    "bench_pool",
    "bench_gateway",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            failures += 1
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}", flush=True)
        print(f"{name}/wall_s,{(time.time()-t0)*1e6:.0f},ok", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
